"""Host-side trace spans: nested wall-clock intervals around the
runtimes' staging work (`pack_problem`, stream ingest/refresh/publish,
serve waves, the bench harness).

Spans measure *host* work — tracing/compile/staging/queueing — never the
device-side solve rounds (those are the on-device `return_trace=`
buffers; see the package docstring). Instrumented library code calls

    with span("pack_problem", nodes=j):
        ...

which is a no-op (one attribute read) unless a `SpanRecorder` is
installed. The harness that wants spans installs one for the duration
of a run:

    with recording(registry) as rec:
        ... run benches / serve ...
    # finished spans are now in registry.spans

Nesting is tracked per thread (each replica thread gets its own depth
stack against the one installed recorder), so a serve-wave span inside
a bench-suite span renders as an indented waterfall in the report CLI.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Iterator

from repro.obs.metrics import Registry, perf_clock

__all__ = ["Span", "SpanRecorder", "install", "recording", "span",
           "uninstall"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished interval. `depth` is the nesting level within its
    thread (0 = top-level); `parent` is the enclosing span's name."""

    name: str
    t_start: float
    t_end: float
    depth: int
    parent: str | None
    thread: str
    attrs: dict[str, Any]

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


class SpanRecorder:
    """Collects finished spans; optionally forwards them to a
    `Registry` (the exporters read `registry.spans`)."""

    def __init__(self, clock: Callable[[], float] = perf_clock,
                 registry: Registry | None = None):
        self.clock = clock
        self.registry = registry
        self._lock = threading.Lock()
        self._local = threading.local()
        self.spans: list[Span] = []

    def _stack(self) -> list[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[None]:
        stack = self._stack()
        depth = len(stack)
        parent = stack[-1] if stack else None
        stack.append(name)
        t0 = self.clock()
        try:
            yield
        finally:
            t1 = self.clock()
            stack.pop()
            sp = Span(name=name, t_start=float(t0), t_end=float(t1),
                      depth=depth, parent=parent,
                      thread=threading.current_thread().name,
                      attrs=dict(attrs))
            with self._lock:
                self.spans.append(sp)
            if self.registry is not None:
                self.registry.record_span(sp)


# The process-wide installed recorder. Library call sites are always-on
# cheap: `span()` reads this once and yields immediately when None.
_installed: SpanRecorder | None = None
_install_lock = threading.Lock()


def install(recorder: SpanRecorder) -> SpanRecorder:
    """Make `recorder` the process-wide span sink (replaces any prior)."""
    global _installed
    with _install_lock:
        _installed = recorder
    return recorder


def uninstall() -> None:
    global _installed
    with _install_lock:
        _installed = None


@contextlib.contextmanager
def recording(registry: Registry | None = None,
              clock: Callable[[], float] = perf_clock
              ) -> Iterator[SpanRecorder]:
    """Install a fresh recorder for the scope, restore the prior one
    after — the harness-side entry point."""
    rec = SpanRecorder(clock=clock, registry=registry)
    with _install_lock:
        global _installed
        prev, _installed = _installed, rec
    try:
        yield rec
    finally:
        with _install_lock:
            _installed = prev


@contextlib.contextmanager
def span(name: str, **attrs: Any) -> Iterator[None]:
    """Library-side span: records into the installed recorder, no-op
    when none is installed."""
    rec = _installed
    if rec is None:
        yield
        return
    with rec.span(name, **attrs):
        yield
