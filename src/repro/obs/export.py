"""Exporters: JSONL and Prometheus text exposition from one `Registry`,
plus run provenance.

JSONL schema (one JSON object per line, `kind` discriminated):

  {"kind": "provenance", "git_sha": ..., "jax_version": ...,
   "device_kind": ..., "platform": ..., "interpret": ..., "t_wall": ...}
  {"kind": "counter",   "name": ..., "value": ...}
  {"kind": "gauge",     "name": ..., "value": ...}
  {"kind": "histogram", "name": ..., "count": ..., "sum": ...,
   "mean": ..., "max": ..., "p50": ..., "p99": ...}
  {"kind": "span",      "name": ..., "t_start": ..., "t_end": ...,
   "depth": ..., "parent": ..., "thread": ..., "attrs": {...}}
  {"kind": "event",     "event": ..., "t": ..., ...free-form fields}

Reserved event names the report CLI (`python -m repro.obs`) renders
specially: ``trace`` (convergence curve — fields `label`, `residuals`,
optionally `bytes`/`broadcasts`/`deliveries`/`active` from an async
trace) and ``latency`` (serve percentiles — fields `label` plus the
`LatencyReport` numbers). Everything else renders generically.

The Prometheus exposition is the text format (counters/gauges as-is,
histograms as summaries with p50/p99 quantiles); names are sanitized to
the Prometheus charset.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
from typing import Any

from repro.obs.metrics import (Counter, Gauge, Histogram, LatencyReport,
                               Registry, wall_clock)

__all__ = [
    "latency_event",
    "provenance",
    "registry_lines",
    "stamp_provenance",
    "to_jsonl",
    "to_prometheus",
    "trace_event",
    "write_jsonl",
]

_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")


def provenance(*, interpret: bool | None = None,
               extra: dict[str, Any] | None = None) -> dict[str, Any]:
    """Run-provenance block: git sha, jax version, device kind,
    platform, interpret-mode flag. Every probe is best-effort — a
    missing git checkout or an unimportable jax degrades to None, never
    raises (benches must stamp their artifacts even on odd hosts)."""
    sha = None
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        sha = None
    jax_version = device_kind = platform = None
    try:
        import jax

        jax_version = jax.__version__
        dev = jax.devices()[0]
        device_kind = dev.device_kind
        platform = dev.platform
    except Exception:
        pass
    block = {
        "git_sha": sha,
        "jax_version": jax_version,
        "device_kind": device_kind,
        "platform": platform,
        "interpret": interpret,
        "t_wall": float(wall_clock()),
    }
    if extra:
        block.update(extra)
    return block


def trace_event(registry: Registry, label: str, trace: Any,
                **fields: Any) -> dict[str, Any]:
    """Record a solver convergence trace (`SolveTrace` /
    `AsyncSolveTrace`) as a ``trace`` event the report CLI renders as a
    convergence table (and, when wire fields are present, as a comm
    frontier row)."""
    return registry.record_event("trace", label=str(label),
                                 **trace.as_lists(), **fields)


def latency_event(registry: Registry, label: str,
                  report: LatencyReport) -> dict[str, Any]:
    """Record a `LatencyReport` as a ``latency`` event (per-wave serve
    percentiles section of the report CLI)."""
    return registry.record_event(
        "latency", label=str(label), count=report.count, p50=report.p50,
        p99=report.p99, mean=report.mean, max=report.max, qps=report.qps)


def registry_lines(registry: Registry,
                   prov: dict[str, Any] | None = None
                   ) -> list[dict[str, Any]]:
    """Serialize one registry to the JSONL record list."""
    lines: list[dict[str, Any]] = []
    if prov is not None:
        lines.append({"kind": "provenance", **prov})
    for name, m in sorted(registry.metrics.items()):
        if isinstance(m, Counter):
            lines.append({"kind": "counter", "name": name,
                          "value": m.value})
        elif isinstance(m, Gauge):
            lines.append({"kind": "gauge", "name": name, "value": m.value})
        elif isinstance(m, Histogram):
            lines.append({"kind": "histogram", "name": name,
                          **m.summary()})
    for sp in registry.spans:
        lines.append({"kind": "span", "name": sp.name,
                      "t_start": sp.t_start, "t_end": sp.t_end,
                      "depth": sp.depth, "parent": sp.parent,
                      "thread": sp.thread, "attrs": dict(sp.attrs)})
    for ev in registry.events:
        lines.append({"kind": "event", **ev})
    return lines


def to_jsonl(registry: Registry,
             prov: dict[str, Any] | None = None) -> str:
    return "\n".join(json.dumps(rec, sort_keys=True)
                     for rec in registry_lines(registry, prov)) + "\n"


def write_jsonl(registry: Registry, path: str,
                prov: dict[str, Any] | None = None) -> str:
    with open(path, "w") as f:
        f.write(to_jsonl(registry, prov))
    return path


def _prom_name(name: str) -> str:
    return _PROM_NAME.sub("_", name)


def to_prometheus(registry: Registry) -> str:
    """Prometheus text exposition of the registry's metrics (spans and
    events are JSONL-only — they are traces, not time series)."""
    out: list[str] = []
    for name, m in sorted(registry.metrics.items()):
        pname = _prom_name(name)
        if m.help:
            out.append(f"# HELP {pname} {m.help}")
        if isinstance(m, Counter):
            out.append(f"# TYPE {pname} counter")
            out.append(f"{pname} {m.value:.17g}")
        elif isinstance(m, Gauge):
            out.append(f"# TYPE {pname} gauge")
            out.append(f"{pname} {m.value:.17g}")
        elif isinstance(m, Histogram):
            s = m.summary()
            out.append(f"# TYPE {pname} summary")
            out.append(f'{pname}{{quantile="0.5"}} {s["p50"]:.17g}')
            out.append(f'{pname}{{quantile="0.99"}} {s["p99"]:.17g}')
            out.append(f"{pname}_sum {s['sum']:.17g}")
            out.append(f"{pname}_count {s['count']}")
    return "\n".join(out) + ("\n" if out else "")


def stamp_provenance(path: str,
                     prov: dict[str, Any] | None = None) -> bool:
    """Inject/refresh a ``provenance`` block in an existing BENCH_*.json
    artifact (top-level dict or list — lists are wrapped under
    ``{"provenance": ..., "results": [...]}``). Returns False when the
    file is missing or unparseable (stamping is best-effort)."""
    if prov is None:
        prov = provenance()
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return False
    if isinstance(payload, dict):
        payload["provenance"] = prov
    else:
        payload = {"provenance": prov, "results": payload}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")
    return True
