"""Synthetic LM token pipeline (no network access — repro band data gate).

A Zipf-distributed Markov-ish stream with injected copy patterns gives the
model something learnable (loss drops measurably within a few hundred
steps), deterministic per seed, with a sharded batch iterator.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_period: int = 64     # every copy_period tokens, repeat a window
    copy_len: int = 16


class SyntheticTokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        # Zipf over the vocab, truncated + renormalized
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def _sequence(self, n: int) -> np.ndarray:
        cfg = self.cfg
        toks = self._rng.choice(cfg.vocab_size, size=n, p=self._p)
        # inject copy structure: window repeats → learnable induction
        for start in range(cfg.copy_period, n - cfg.copy_len,
                           cfg.copy_period):
            src = start - cfg.copy_period
            toks[start:start + cfg.copy_len] = toks[src:src + cfg.copy_len]
        return toks.astype(np.int32)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        while True:
            seqs = np.stack([self._sequence(cfg.seq_len + 1)
                             for _ in range(cfg.batch_size)])
            yield {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}

    def batches(self, num: int) -> Iterator[dict]:
        it = iter(self)
        for _ in range(num):
            yield next(it)
