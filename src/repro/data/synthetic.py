"""Synthetic stand-ins for the paper's six regression datasets.

The container has no network access (repro band 2/5: data gate), so we
simulate each libsvm/UCI dataset with a generator that preserves its (d, N)
and produces a smooth nonlinear teacher — a random ground-truth function
drawn from (an RF approximation of) a Gaussian RKHS plus heteroscedastic
noise — which is exactly the model class where KRR comparisons are
meaningful. Preprocessing follows the paper: x scaled to [0,1], y to [-1,1],
50/50 train/test per node.

Partitioners implement the paper's §IV protocols:
  * non-IID by mean |y|  (sort |y| descending, deal out contiguously)
  * non-IID by ‖x‖₂      (ditto on input norms)
  * imbalanced           N_j = (2j−1)N/100 for J=10 (generalized)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.dekrr import NodeData

DATASET_SPECS: dict[str, tuple[int, int]] = {
    # name: (d, N) from Tab. 1
    "houses": (8, 20640),
    "air_quality": (13, 9357),
    "energy": (27, 19735),
    "twitter": (77, 98704),
    "toms_hardware": (96, 29179),
    "wave": (148, 63600),
}


@dataclasses.dataclass
class Dataset:
    name: str
    x: np.ndarray  # [d, N], scaled to [0, 1]
    y: np.ndarray  # [N],   scaled to [-1, 1]

    @property
    def dim(self) -> int:
        return self.x.shape[0]

    @property
    def num_samples(self) -> int:
        return self.x.shape[1]


def make_dataset(name: str, *, seed: int = 0, subsample: int | None = None,
                 noise: float = 0.05, teacher_features: int = 64,
                 teacher_components: int = 4) -> Dataset:
    """Generate the synthetic stand-in for ``name`` (see DATASET_SPECS).

    The teacher is *spatially modulated*: a soft partition-of-unity over input
    space gates M component functions, each drawn from a Gaussian RKHS with
    its own bandwidth (log-spaced). Different regions of input space are
    therefore dominated by different frequency bands — the regime real
    tabular data exhibits and the one DDRF is designed for: under non-IID
    partitions each node sees one band and benefits from selecting features
    matched to it, while data-independent shared RFF must spread its budget
    over all bands.
    """
    if name not in DATASET_SPECS:
        raise KeyError(f"unknown dataset {name!r}; have {list(DATASET_SPECS)}")
    d, n = DATASET_SPECS[name]
    if subsample is not None:
        n = min(n, subsample)
    # stable per-dataset seed (Python's hash() is randomized per process)
    name_seed = int.from_bytes(
        __import__("hashlib").md5(name.encode()).digest()[:4], "little")
    rng = np.random.default_rng(seed + name_seed % (2**31))

    # Inputs: correlated features squashed to [0,1] (tabular-like marginals).
    mix = rng.normal(size=(d, d)) / np.sqrt(d)
    raw = mix @ rng.normal(size=(d, n)) + 0.3 * rng.normal(size=(d, n))
    x = 1.0 / (1.0 + np.exp(-raw))                       # [d, N] in (0,1)

    # Soft partition of unity: softmax over random linear gates.
    m = teacher_components
    gate_w = rng.normal(size=(m, d)) * 3.0 / np.sqrt(d)
    gate_b = rng.normal(size=(m, 1))
    logits = gate_w @ (x - 0.5) + gate_b                 # [M, N]
    logits -= logits.max(axis=0, keepdims=True)
    gates = np.exp(logits)
    gates /= gates.sum(axis=0, keepdims=True)

    # Component functions: random Fourier, log-spaced bandwidths.
    sigmas = np.geomspace(0.25 * np.sqrt(d), 2.0 * np.sqrt(d), m)
    f = np.zeros(n)
    for c in range(m):
        omega = rng.normal(size=(teacher_features, d)) / sigmas[c]
        bias = rng.uniform(0, 2 * np.pi, size=(teacher_features, 1))
        coef = rng.normal(size=teacher_features) / np.sqrt(teacher_features)
        f += gates[c] * (coef @ np.cos(omega @ x + bias))

    # Heteroscedastic noise (stronger where ‖x‖ is large → non-IID splits by
    # ‖x‖ also induce noise heterogeneity across nodes, as in real sensors).
    scale = noise * (1.0 + np.linalg.norm(x, axis=0) / np.sqrt(d))
    y = f + rng.normal(size=n) * scale

    # Paper preprocessing: y → [-1, 1].
    y = 2.0 * (y - y.min()) / max(y.max() - y.min(), 1e-12) - 1.0
    return Dataset(name=name, x=x.astype(np.float64), y=y.astype(np.float64))


# ------------------------------------------------------------- partitioners
def _deal(order: np.ndarray, sizes: list[int]) -> list[np.ndarray]:
    out, start = [], 0
    for s in sizes:
        out.append(order[start:start + s])
        start += s
    return out


def equal_sizes(n: int, num_nodes: int) -> list[int]:
    base = n // num_nodes
    sizes = [base] * num_nodes
    for i in range(n - base * num_nodes):
        sizes[i] += 1
    return sizes


def imbalanced_sizes(n: int, num_nodes: int) -> list[int]:
    """Paper §IV-B2: N_j = (2j−1)/J² · N (for J=10: (2j−1)N/100)."""
    weights = np.array([2 * j - 1 for j in range(1, num_nodes + 1)], float)
    weights /= weights.sum()
    sizes = np.floor(weights * n).astype(int)
    sizes[-1] += n - sizes.sum()
    return sizes.tolist()


def partition(
    ds: Dataset,
    num_nodes: int,
    *,
    mode: str = "iid",
    sizes: list[int] | None = None,
    seed: int = 0,
) -> list[NodeData]:
    """Split a dataset across nodes. mode: iid | noniid_y | noniid_xnorm."""
    import jax.numpy as jnp

    n = ds.num_samples
    rng = np.random.default_rng(seed)
    if sizes is None:
        sizes = equal_sizes(n, num_nodes)
    if sum(sizes) != n:
        raise ValueError(f"sizes sum {sum(sizes)} != N {n}")

    if mode == "iid":
        order = rng.permutation(n)
    elif mode == "noniid_y":
        order = np.argsort(-np.abs(ds.y))      # descending mean |y| per node
    elif mode == "noniid_xnorm":
        order = np.argsort(-np.linalg.norm(ds.x, axis=0))
    else:
        raise ValueError(f"unknown partition mode {mode!r}")

    shards = _deal(order, sizes)
    return [
        NodeData(x=jnp.asarray(ds.x[:, idx]), y=jnp.asarray(ds.y[idx]))
        for idx in shards
    ]


def train_test_split_nodes(
    nodes: list[NodeData], *, seed: int = 0
) -> tuple[list[NodeData], list[NodeData]]:
    """Paper: each node trains on half its local data, tests on the rest."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    train, test = [], []
    for nd in nodes:
        n = nd.num_samples
        perm = rng.permutation(n)
        half = n // 2
        tr, te = perm[:half], perm[half:]
        x = np.asarray(nd.x)
        y = np.asarray(nd.y)
        train.append(NodeData(x=jnp.asarray(x[:, tr]), y=jnp.asarray(y[tr])))
        test.append(NodeData(x=jnp.asarray(x[:, te]), y=jnp.asarray(y[te])))
    return train, test


def pooled(nodes: list[NodeData]) -> NodeData:
    import jax.numpy as jnp

    x = jnp.concatenate([nd.x for nd in nodes], axis=1)
    y = jnp.concatenate([nd.y.reshape(-1) for nd in nodes])
    return NodeData(x=x, y=y)
