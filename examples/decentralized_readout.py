"""The paper's technique on top of the assigned-architecture zoo:
frozen-backbone features → per-node DDRF selection → DeKRR-DDRF consensus.

Ten nodes each hold a non-IID shard of (sequence → scalar) regression data;
features are the backbone's mean-pooled final hidden states. Because the
decision-function consensus never requires identical feature maps, each
node's RF head adapts to its local feature distribution — the same
flexibility the paper demonstrates on tabular data, here on transformer
representations.

  PYTHONPATH=src python examples/decentralized_readout.py --arch smollm_135m
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--num-seqs", type=int, default=600)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.core import (DeKRRConfig, DeKRRSolver, NodeData, circulant,
                            rse, select_features)
    from repro.models.model import Model

    spec = get_arch(args.arch)
    cfg = spec.config.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # --- synthetic seq→scalar task: y depends on token statistics -----------
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (args.num_seqs, args.seq), 0,
                              cfg.vocab_size)
    # frozen-backbone features: mean-pooled last hidden state
    @jax.jit
    def featurize_batch(tb):
        logits, _ = model.forward(params, tokens=tb)
        return logits.mean(axis=1)          # [B, V] pooled readout features

    feats = []
    for i in range(0, args.num_seqs, 64):
        feats.append(featurize_batch(toks[i:i + 64]))
    feats = jnp.concatenate(feats)[:, :64].astype(jnp.float64)  # [N, 64]
    w_true = jax.random.normal(jax.random.PRNGKey(2), (64,), jnp.float64)
    y = jnp.tanh(feats @ w_true) + 0.05 * jax.random.normal(
        jax.random.PRNGKey(3), (args.num_seqs,), jnp.float64)

    # --- non-IID split across 10 nodes (sorted |y|), DeKRR-DDRF -------------
    topo = circulant(10, (1, 2))
    order = jnp.argsort(-jnp.abs(y))
    x_all = feats[order].T                  # [d=64, N]
    y_all = y[order]
    n = args.num_seqs
    per = n // 10
    train, test = [], []
    for j in range(10):
        sl = slice(j * per, (j + 1) * per)
        xj, yj = x_all[:, sl], y_all[sl]
        h = per // 2
        train.append(NodeData(x=xj[:, :h], y=yj[:h]))
        test.append(NodeData(x=xj[:, h:], y=yj[h:]))

    keys = jax.random.split(jax.random.PRNGKey(4), 10)
    fmaps = [select_features(keys[j], 64, 16, 2.0, train[j].x, train[j].y,
                             method="energy", candidate_ratio=10)
             for j in range(10)]
    ntr = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.01 * ntr))
    st = solver.solve_exact()
    ys = jnp.concatenate([t.y for t in test])
    pred = jnp.concatenate(
        [solver.predict(st.theta, test[j].x, node=j) for j in range(10)])
    print(f"backbone={cfg.name}  DeKRR-DDRF readout RSE = "
          f"{rse(pred, ys):.4f} over {10} nodes")
    # local-only comparison for the starved node
    from repro.core.rff import featurize as fz
    z = fz(fmaps[9], train[9].x)
    th = jnp.linalg.solve(z @ z.T + 1e-6 * z.shape[1] * jnp.eye(z.shape[0]),
                          z @ train[9].y)
    pooled_x = jnp.concatenate([t.x for t in test], axis=1)
    r_local = rse(th @ fz(fmaps[9], pooled_x), ys)
    r_cons = rse(solver.predict(st.theta, pooled_x, node=9), ys)
    print(f"starved node on pooled test: local-only {r_local:.3f} → "
          f"consensus {r_cons:.3f}")


if __name__ == "__main__":
    main()
