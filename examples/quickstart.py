"""Quickstart: decentralized KRR with data-dependent random features.

Ten nodes on the paper's circulant C_10(1,2) network each select their own
random features from local data (energy scoring, D0/D = 20), build the
Eq. 17 auxiliaries with one round of neighbor exchange, then iterate the
Eq. 19 update communicating only θ_j.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import (DKLA, DKLAConfig, DeKRRConfig, DeKRRSolver,
                        circulant, rse, sample_rff, select_features)
from repro.data.synthetic import (make_dataset, partition,
                                  train_test_split_nodes)


def main():
    # --- data: "houses" stand-in, non-IID split by |y| ----------------------
    ds = make_dataset("houses", subsample=2000, seed=0)
    topo = circulant(10, (1, 2))
    train, test = train_test_split_nodes(
        partition(ds, 10, mode="noniid_y"))
    n = sum(t.num_samples for t in train)
    print(f"dataset d={ds.dim} N={ds.num_samples}, J=10, |N_j|=4")

    # --- per-node data-dependent features (the paper's point) ---------------
    keys = jax.random.split(jax.random.PRNGKey(0), 10)
    fmaps = [
        select_features(keys[j], ds.dim, 30, 1.0, train[j].x, train[j].y,
                        method="energy", candidate_ratio=20)
        for j in range(10)
    ]

    # --- Algorithm 1 ---------------------------------------------------------
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.005 * n,
                                     num_iters=400))
    state = solver.solve()                       # decentralized iteration
    limit = solver.solve_exact()                 # its limit point (reference)

    ys = jnp.concatenate([t.y for t in test])
    pred = jnp.concatenate(
        [solver.predict(state.theta, test[j].x, node=j) for j in range(10)])
    pred_lim = jnp.concatenate(
        [solver.predict(limit.theta, test[j].x, node=j) for j in range(10)])
    print(f"DeKRR-DDRF   RSE = {rse(pred, ys):.4f} "
          f"(limit point {rse(pred_lim, ys):.4f}, "
          f"spectral radius {solver.spectral_radius():.4f})")

    # --- DKLA baseline (identical features required on every node) ----------
    fmap = sample_rff(jax.random.PRNGKey(50), ds.dim, 30, 1.0)
    dkla = DKLA(topo, fmap, train, DKLAConfig(lam=1e-6, num_iters=400))
    th = dkla.solve()
    pred_d = jnp.concatenate(
        [dkla.predict(th, test[j].x, node=j) for j in range(10)])
    print(f"DKLA (RFF)   RSE = {rse(pred_d, ys):.4f}")


if __name__ == "__main__":
    main()
