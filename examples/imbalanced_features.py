"""The paper's imbalanced-data experiment (Fig. 3/4): N_j = (2j−1)N/100,
equal D_j vs √N_j-proportional D_j at the same communication budget.

  PYTHONPATH=src python examples/imbalanced_features.py [--fast]
"""
import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from benchmarks.paper_fig3_imbalanced import run as fig3
    from benchmarks.paper_fig4_pernode import run as fig4

    rows = fig3(fast=args.fast)
    print("\n=== Fig. 3 (imbalanced twitter stand-in) ===")
    for dbar, r_dkla, r_dd, r_eq, r_var in rows:
        print(f"D̄={dbar:4d}: DKLA={r_dkla:.4f}  DKLA-DDRF={r_dd:.4f}  "
              f"ours-equalD={r_eq:.4f}  ours-√N D={r_var:.4f}")

    eq, var = fig4(fast=args.fast)
    print("\n=== Fig. 4 per-node RSE ===")
    print("node:   " + "  ".join(f"{j+1:5d}" for j in range(10)))
    print("equal:  " + "  ".join(f"{v:.3f}" for v in eq))
    print("sqrtN:  " + "  ".join(f"{v:.3f}" for v in var))


if __name__ == "__main__":
    main()
