"""End-to-end driver: the paper's non-IID experiment (Tab. 2 protocol) on
all six datasets — DKLA vs DKLA-DDRF vs DeKRR-DDRF at the paper's D̄,
penalty selected on a validation split, repeated over seeds.

  PYTHONPATH=src python examples/noniid_benchmark.py [--fast]
"""
import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from benchmarks.paper_table2 import run

    rows = run(fast=args.fast)
    print("\n=== Table 2 (synthetic stand-ins) ===")
    print(f"{'dataset':16s} {'D̄':>5s} {'DKLA':>8s} {'DKLA-DDRF':>10s} "
          f"{'Ours':>8s} {'Δ%':>7s}")
    for name, dbar, r_dkla, r_dd, r_ours, imp in rows:
        print(f"{name:16s} {dbar:5d} {r_dkla:8.4f} {r_dd:10.4f} "
              f"{r_ours:8.4f} {imp:6.1f}%")


if __name__ == "__main__":
    main()
