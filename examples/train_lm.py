"""End-to-end LM training driver: train a model from the assigned-arch zoo
on the synthetic token pipeline for a few hundred steps and verify the loss
drops. Reduced configs by default (CPU container); --full selects the exact
assigned configuration (needs real accelerators).

  PYTHONPATH=src python examples/train_lm.py --arch smollm_135m --steps 200
"""
import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.data.tokens import SyntheticTokenPipeline, TokenPipelineConfig
    from repro.train.loop import train_loop
    from repro.train.optim import AdamWConfig

    spec = get_arch(args.arch)
    if spec.input_kind != "tokens":
        raise SystemExit(f"{args.arch} needs a frontend stub — "
                         "use a [dense]/[moe]/[ssm] arch for this driver")
    cfg = spec.config if args.full else spec.config.reduced()
    print(f"training {cfg.name} ({'full' if args.full else 'reduced'}): "
          f"{cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size}")

    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, batch_size=args.batch, seq_len=args.seq))
    opt = AdamWConfig(lr=3e-4, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 5))
    state, history = train_loop(cfg, opt, iter(pipe), args.steps,
                                log_every=max(args.steps // 20, 1))
    first, last = history[0]["loss"], history[-1]["loss"]
    assert last < first, "loss did not decrease"
    print(f"\nloss {first:.4f} → {last:.4f} "
          f"({100*(first-last)/first:.1f}% reduction) — training works")


if __name__ == "__main__":
    main()
